"""Paged KV pool + continuous batching: the exactness and memory contract.

The ISSUE-7 acceptance criteria, as tests:

  * the paged engine's tokens are **bit-identical** to the dense
    slot-table engine's for the same requests — mixed-length prompts
    admitted in a single continuous-batching round, dense and MoE archs,
    single-class and class-sharded mixed (8 forced host devices), with
    ``ShardProvenance`` still proving the per-class programs;
  * EOS stopping retires a slot mid-stream, frees its pages immediately,
    and the streams of every other request are unperturbed; freed pages
    are reused by later admissions with tokens identical to a fresh
    engine's;
  * pool exhaustion *defers* admission (FIFO, counted) without
    corrupting live slots — every request still completes, bit-identical
    to the dense engine;
  * a retired (dead) lane is inert: its attention output is exactly
    zero and its (stale cache, runaway position) can never change live
    rows — linear and ring/sliding-window masks both (the phantom-lane
    masking clamp regression);
  * the paged allocator itself: all-or-nothing reservation, LIFO reuse,
    pod partitioning, sentinel/localize arithmetic.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, DeviceClass, biglittle_classes
from repro.models import layers as L
from repro.models import model_zoo as Z
from repro.models import transformer as TX
from repro.runtime.paging import PagePool, PageSpec, SENTINEL, divisor_page_size
from repro.runtime.serving import ServingEngine

RNG = np.random.default_rng(23)

# One row-local dense arch and one MoE arch (capacity routing couples
# batch rows — the hard case for phantom-lane exactness).
ARCHS = ["internlm2-1.8b", "mixtral-8x7b"]


@pytest.fixture(scope="module")
def zoo():
    out = {}
    for name in ARCHS:
        cfg = get_config(name).reduced()
        out[name] = (cfg, Z.init_params(jax.random.PRNGKey(0), cfg))
    return out


def _single(**kw):
    kw.setdefault("strategy", "ca-das")
    kw.setdefault("batch_tile", 1)
    return AsymmetricMesh(
        [DeviceClass(name="big", n_pods=1, chips_per_pod=1, rel_throughput=1.0)],
        **kw,
    )


def _biglittle(**kw):
    kw.setdefault("strategy", "ca-das")
    kw.setdefault("batch_tile", 1)
    return AsymmetricMesh(biglittle_classes(chips_per_pod=1), **kw)


def _run_engine(cfg, params, asym, requests, *, paged, seq_cap=32,
                slots_per_pod=4, class_sharded="off", **kw):
    eng = ServingEngine(
        cfg, params, asym, seq_cap=seq_cap, slots_per_pod=slots_per_pod,
        class_sharded=class_sharded, paged=paged, **kw,
    )
    rids = [eng.submit(p, g) for p, g in requests]
    done = {c.rid: c for c in eng.run()}
    assert set(done) == set(rids)
    return eng, done


# ---------------------------------------------------------------------------
# Bit-identity: paged vs dense, mixed lengths, one admission round
# ---------------------------------------------------------------------------


class TestPagedBitIdentity:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_mixed_length_single_round(self, zoo, arch):
        """Mixed-length prompts admit in ONE round and the paged engine's
        tokens equal the dense engine's bit-for-bit (free lanes decode as
        phantom pad rows in both; mid-stream retirements leave dead lanes
        in both)."""

        cfg, params = zoo[arch]
        prompts = RNG.integers(0, cfg.vocab, (3, 9), dtype=np.int32)
        reqs = [(prompts[0][:4], 5), (prompts[1][:9], 7), (prompts[2][:6], 3)]
        ed, dd = _run_engine(cfg, params, _single(), reqs, paged="off")
        ep, dp = _run_engine(cfg, params, _single(), reqs, paged="on",
                             page_size=8)
        assert ed.stats.admission_rounds == 1 == ep.stats.admission_rounds
        for rid in dd:
            assert np.array_equal(dd[rid].tokens, dp[rid].tokens), (arch, rid)
        # All pages returned once every slot retired; the phantom lanes
        # stay resident by design.
        assert ep.pool.pages_live == ep.phantom.size
        ks = ep.kv_stats()
        assert ks["paged"] and ks["peak_live_pages"] > ks["pages_live"]
        assert ks["page_bytes"] * ks["n_pages"] == ks["arena_kv_bytes"]

    @pytest.mark.parametrize("arch", ARCHS)
    @pytest.mark.skipif(jax.device_count() < 2, reason="needs 2 host devices")
    def test_mixed_class_sharded(self, zoo, arch):
        """The class-sharded mixed step (pod-partitioned arena, localized
        page ids) is bit-identical to the dense mixed step, and
        ShardProvenance still proves one program per class."""

        cfg, params = zoo[arch]
        prompts = RNG.integers(0, cfg.vocab, (5, 9), dtype=np.int32)
        plens, gens = [4, 9, 6, 9, 5], [5, 3, 7, 4, 6]
        reqs = [(prompts[i][:plens[i]], gens[i]) for i in range(5)]
        ed, dd = _run_engine(cfg, params, _biglittle(), reqs, paged="off",
                             class_sharded="auto")
        ep, dp = _run_engine(cfg, params, _biglittle(), reqs, paged="on",
                             page_size=8, class_sharded="auto")
        assert ed.mixed and ep.mixed
        assert [(p.pod, p.device_class) for p in ep.provenance] == [
            (0, "big"), (1, "little"),
        ]
        for rid in dd:
            assert np.array_equal(dd[rid].tokens, dp[rid].tokens), (arch, rid)

    def test_paged_auto_and_unsupported(self, zoo):
        """"auto" pages pure KV-cache archs and silently stays dense where
        state cannot page; "on" raises there."""

        cfg, params = zoo["internlm2-1.8b"]
        eng = ServingEngine(cfg, params, _single(), seq_cap=16,
                            class_sharded="off", paged="auto")
        assert eng.pool is not None

        for unsupported in ("mamba2-1.3b", "zamba2-2.7b"):
            mcfg = get_config(unsupported).reduced()
            mparams = Z.init_params(jax.random.PRNGKey(0), mcfg)
            auto = ServingEngine(mcfg, mparams, _single(), seq_cap=16,
                                 class_sharded="off", paged="auto")
            assert auto.pool is None, unsupported
            with pytest.raises(ValueError, match="paged='on'"):
                ServingEngine(mcfg, mparams, _single(), seq_cap=16,
                              class_sharded="off", paged="on")


# ---------------------------------------------------------------------------
# EOS stopping + page reuse
# ---------------------------------------------------------------------------


class TestEosAndReuse:
    def test_eos_frees_pages_mid_stream_without_perturbing_others(self, zoo):
        """A request that emits EOS retires mid-stream (pages freed,
        counted as completed_eos); every other request's stream is
        bit-identical to the run without EOS."""

        cfg, params = zoo["internlm2-1.8b"]
        prompts = RNG.integers(0, cfg.vocab, (3, 6), dtype=np.int32)
        reqs = [(prompts[i], 6) for i in range(3)]
        _, base = _run_engine(cfg, params, _single(), reqs, paged="on")
        # Pick the token rid 0 generates mid-stream as the EOS id: the
        # rerun must stop that request right there.
        eos = int(base[0].tokens[6 + 2])
        eng, done = _run_engine(cfg, params, _single(), reqs, paged="on",
                                eos_id=eos)
        assert eng.stats.completed_eos >= 1
        assert eng.stats.completed_eos + eng.stats.completed_budget == 3
        for rid, comp in done.items():
            full = base[rid].tokens
            if comp.stop == "eos":
                n = len(comp.tokens)
                assert comp.tokens[-1] == eos
                assert np.array_equal(comp.tokens, full[:n])
            else:
                assert eos not in full[6:]  # budget rows never saw EOS
                assert np.array_equal(comp.tokens, full)
        # EOS parity with the dense engine, bit for bit.
        engd, doned = _run_engine(cfg, params, _single(), reqs, paged="off",
                                  eos_id=eos)
        for rid in done:
            assert np.array_equal(done[rid].tokens, doned[rid].tokens)
            assert done[rid].stop == doned[rid].stop
        assert engd.stats.completed_eos == eng.stats.completed_eos

    def test_page_reuse_after_completion_identical_to_fresh(self, zoo):
        """A second wave reuses the pages the first wave freed (LIFO) and
        its tokens are bit-identical to a fresh paged engine's."""

        cfg, params = zoo["internlm2-1.8b"]
        w1 = RNG.integers(0, cfg.vocab, (4, 6), dtype=np.int32)
        w2 = RNG.integers(0, cfg.vocab, (4, 6), dtype=np.int32)
        eng = ServingEngine(cfg, params, _single(), seq_cap=32,
                            slots_per_pod=4, class_sharded="off", paged="on",
                            page_size=8)
        eng.generate(w1, 4)
        live_between = eng.pool.pages_live
        assert live_between == eng.phantom.size  # wave-1 pages all freed
        got = eng.generate(w2, 4)

        fresh = ServingEngine(cfg, params, _single(), seq_cap=32,
                              slots_per_pod=4, class_sharded="off", paged="on",
                              page_size=8)
        assert np.array_equal(got, fresh.generate(w2, 4))
        assert eng.stats.completed == 8
        # Reuse, not growth: the second wave never allocated beyond the
        # first wave's high-water mark.
        assert eng.pool.peak_live == fresh.pool.peak_live


# ---------------------------------------------------------------------------
# Pool exhaustion defers (never corrupts)
# ---------------------------------------------------------------------------


class TestExhaustion:
    def test_exhaustion_defers_and_completes(self, zoo):
        """A pool sized for two in-flight requests serves four: admission
        defers (counted), live slots are untouched, every request
        completes bit-identical to the dense engine."""

        cfg, params = zoo["internlm2-1.8b"]
        prompts = RNG.integers(0, cfg.vocab, (4, 8), dtype=np.int32)
        reqs = [(prompts[i], 8) for i in range(4)]
        # page_size 8, seq_cap 32 -> W = 4; each request reserves
        # ceil(16/8) = 2 pages.  pool = 8 pages = phantom lane (4) + two
        # requests' worth: the 3rd admission must defer until a retire.
        ep, dp = _run_engine(cfg, params, _single(), reqs, paged="on",
                             page_size=8, pool_pages=8)
        assert ep.stats.admission_deferrals >= 1
        assert ep.stats.admission_rounds >= 2
        ed, dd = _run_engine(cfg, params, _single(), reqs, paged="off")
        for rid in dd:
            assert np.array_equal(dd[rid].tokens, dp[rid].tokens)

    def test_infeasible_request_raises(self, zoo):
        """A request whose reservation can never fit (even an empty pool)
        fails loudly instead of spinning."""

        cfg, params = zoo["internlm2-1.8b"]
        eng = ServingEngine(cfg, params, _single(), seq_cap=32,
                            slots_per_pod=4, class_sharded="off", paged="on",
                            page_size=8, pool_pages=5)  # phantom takes 4
        eng.submit(np.ones(8, np.int32), 8)  # needs 2 pages, 1 free
        with pytest.raises(RuntimeError, match="no progress"):
            eng.run()


# ---------------------------------------------------------------------------
# Dead-lane inertness (the phantom-lane masking clamp regression)
# ---------------------------------------------------------------------------


class TestDeadLaneMasking:
    @pytest.mark.parametrize("window", [None, 8], ids=["linear", "ring"])
    def test_dead_lane_never_changes_live_rows(self, window):
        """A retired lane — live=False, position aged arbitrarily far past
        the cache — contributes exactly zero output, and scrambling its
        cache/position leaves live rows bit-identical (both mask shapes)."""

        acfg = L.AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, d_head=8,
                            window=window)
        p = L.init_attention(jax.random.PRNGKey(1), acfg)
        b = 3
        s_cache = window or 16
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(b, 1, 32)), L.COMPUTE_DTYPE)
        ck = jnp.asarray(rng.normal(size=(b, s_cache, 2, 8)), L.COMPUTE_DTYPE)
        cv = jnp.asarray(rng.normal(size=(b, s_cache, 2, 8)), L.COMPUTE_DTYPE)
        pos = jnp.asarray([5, s_cache + 7, 3], jnp.int32)  # lane 1 is dead
        live = jnp.asarray([True, False, True])

        h1, _ = L.decode_attention(p, x, acfg, ck, cv, pos, live=live)
        assert np.all(np.isfinite(np.asarray(h1, np.float32)))
        assert np.all(np.asarray(h1[1], np.float32) == 0.0)

        # Scramble the dead lane: garbage cache, runaway position.
        ck2 = ck.at[1].set(1e4)
        cv2 = cv.at[1].set(-1e4)
        pos2 = pos.at[1].set(3 * s_cache + 1)
        h2, _ = L.decode_attention(p, x, acfg, ck2, cv2, pos2, live=live)
        assert np.array_equal(np.asarray(h1[0]), np.asarray(h2[0]))
        assert np.array_equal(np.asarray(h1[2]), np.asarray(h2[2]))

    def test_live_lane_past_cache_is_finite(self):
        """The clamp itself: a LIVE linear-mask row whose position reached
        the cache length attends the full cache (finite softmax) instead
        of masking every key (NaN) — the bug the clamp fixed."""

        acfg = L.AttnConfig(d_model=32, n_heads=4, n_kv_heads=2, d_head=8)
        p = L.init_attention(jax.random.PRNGKey(2), acfg)
        rng = np.random.default_rng(6)
        s_cache = 8
        x = jnp.asarray(rng.normal(size=(2, 1, 32)), L.COMPUTE_DTYPE)
        ck = jnp.asarray(rng.normal(size=(2, s_cache, 2, 8)), L.COMPUTE_DTYPE)
        cv = jnp.asarray(rng.normal(size=(2, s_cache, 2, 8)), L.COMPUTE_DTYPE)
        pos = jnp.asarray([s_cache, 2], jnp.int32)
        h, _ = L.decode_attention(p, x, acfg, ck, cv, pos)
        assert np.all(np.isfinite(np.asarray(h, np.float32)))


# ---------------------------------------------------------------------------
# The allocator
# ---------------------------------------------------------------------------


class TestPagePool:
    def test_divisor_page_size(self):
        assert divisor_page_size(32, 8) == 8
        assert divisor_page_size(32, 12) == 8   # rounds down to a divisor
        assert divisor_page_size(32, 100) == 32
        assert divisor_page_size(7, 4) == 1     # prime cache length

    def test_all_or_nothing_and_lifo_reuse(self):
        spec = PageSpec(page_size=4, pages_per_slot=4, pages_per_pod=6,
                        n_pods=1)
        pool = PagePool(spec, c_max=2)
        assert pool.alloc(0, 16)          # 4 pages
        assert not pool.alloc(1, 12)      # needs 3, only 2 left: untouched
        assert np.all(pool.table[1] == SENTINEL)
        assert pool.pages_live == 4
        freed_pages = list(pool.table[0])
        assert pool.free_slot(0) == 4
        assert pool.pages_live == 0
        assert pool.alloc(1, 12)
        # LIFO: the pages slot 0 just returned come straight back.
        assert set(pool.table[1][:3]) <= set(freed_pages)
        # Growing an existing reservation allocates only the missing tail.
        assert pool.alloc(1, 16)
        assert pool.pages_live == 4 and pool.peak_live == 4

    def test_pod_partitioning_and_localize(self):
        spec = PageSpec(page_size=4, pages_per_slot=2, pages_per_pod=3,
                        n_pods=2)
        pool = PagePool(spec, c_max=2)
        assert pool.alloc(0, 8)   # 2 pages from pod 0's partition
        assert pool.alloc(2, 8)   # 2 pages from pod 1's
        assert np.all(pool.table[0] < 3)
        assert np.all((pool.table[2] >= 3) & (pool.table[2] < 6))
        table = pool.table.copy()
        local = pool.localize(table, np.asarray([0, 0, 1, 1]))
        assert np.all(local[2] == pool.table[2] - 3)
        assert np.all(local[0] == pool.table[0])
        # SENTINEL entries stay far out of range after localization.
        assert np.all(local[1] > spec.n_pages)
        # Pod 0 exhaustion (one page free, two needed) is all-or-nothing
        # and does not touch pod 1's free list.
        assert not pool.alloc(1, 8)
        assert np.all(pool.table[1] == SENTINEL)
        assert pool.alloc(3, 4)

    def test_phantom_rows(self):
        spec = PageSpec(page_size=4, pages_per_slot=2, pages_per_pod=8,
                        n_pods=2)
        shared = PagePool(spec, c_max=2).alloc_phantom()
        assert shared.shape == (2, 2)
        per_slot = PagePool(spec, c_max=2).alloc_phantom(per_slot=True)
        assert per_slot.shape == (4, 2)
        # Each phantom row draws from its owner pod's partition.
        assert np.all(per_slot[:2] < 8) and np.all(per_slot[2:] >= 8)
        small = PagePool(
            PageSpec(page_size=4, pages_per_slot=2, pages_per_pod=1,
                     n_pods=1), c_max=1)
        with pytest.raises(ValueError, match="pool too small"):
            small.alloc_phantom()


# ---------------------------------------------------------------------------
# Telemetry + report rollup
# ---------------------------------------------------------------------------


class TestPagedTelemetry:
    def test_page_instants_metrics_and_rollup(self, zoo, tmp_path):
        """With observability on, admissions/retirements emit page
        alloc/free instants and pool gauges; the report CLI's rollup
        recovers the pool's true high-water mark from the trace."""

        from repro import observability as OBS
        from repro.observability import report as R
        from repro.observability import trace as TR

        cfg, params = zoo["internlm2-1.8b"]
        prompts = RNG.integers(0, cfg.vocab, (3, 6), dtype=np.int32)
        OBS.enable()
        try:
            eng, _ = _run_engine(cfg, params, _single(),
                                 [(p, 4) for p in prompts], paged="on",
                                 page_size=8)
            snap = OBS.REGISTRY.snapshot()
            buf = TR.get_buffer()
            events = list(buf.events)
        finally:
            OBS.disable()
        names = {e.name for e in events}
        assert {"engine.page_alloc", "engine.page_free"} <= names
        assert "engine_kv_pool_pages_free" in snap
        assert "engine_kv_pool_pages_live" in snap
        assert "engine_page_allocs_total" in snap

        instants = [
            {"name": e.name, "ts": e.ts, "args": e.args}
            for e in events if e.ph == "i"
        ]
        kv = R.kv_pool_rollup(instants)
        assert kv is not None
        assert kv["peak_live_pages"] == eng.pool.peak_live
        assert kv["final_live_pages"] == eng.pool.pages_live
        assert kv["pages_allocated"] >= kv["pages_freed"] > 0
        assert R.kv_pool_rollup([]) is None

"""Telemetry subsystem: spans, metrics, probe, report, engine wiring.

The ISSUE-6 acceptance criteria, as tests:

  * the span stack nests/restores correctly under exceptions, concurrent
    threads, and interleaved asyncio tasks (the same contextvar
    discipline ``test_execution.py`` proves for ``ExecutionContext``);
  * the trace buffer is bounded (oldest events drop, counted) and both
    export formats round-trip through the report CLI;
  * the metrics registry validates names/labels, registers idempotently,
    and renders well-formed Prometheus text exposition;
  * the step-time probe is inert while observability is off (off-is-free)
    and, when active, reports per-pod times proportional to the units
    each pod ran — occupancy cancels in the scheduler's rate;
  * a traced engine run emits per-class decode spans and the engine
    metric families, and ``EngineStats.snapshot()`` is the one JSON
    reporting surface;
  * the calibration loop CLOSES: with the probe measuring real wall
    times that contradict the typed big:little ratio, the dynamic
    scheduler drifts and re-derives the chunk table — a rebalance driven
    entirely by *measured* signal, visible in the trace.
"""

import json
import threading
import time

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.asymmetric import AsymmetricMesh, biglittle_classes
from repro.models import model_zoo as Z
from repro.observability import metrics as MET
from repro.observability import report, trace as T
from repro.observability.probe import StepTimeProbe
from repro.runtime.serving import ServingEngine


@pytest.fixture(autouse=True)
def _trace_off():
    """Every test starts and ends with tracing disabled (module switch)."""

    T.disable()
    yield
    T.disable()


def _biglittle(**kw):
    kw.setdefault("strategy", "ca-das")
    kw.setdefault("batch_tile", 1)
    return AsymmetricMesh(biglittle_classes(chips_per_pod=1), **kw)


# ---------------------------------------------------------------------------
# Span stack: nesting, exceptions, threads, asyncio
# ---------------------------------------------------------------------------


class TestSpans:
    def test_disabled_is_noop_singleton(self):
        # Off-is-free: no allocation, no state — the same reusable object.
        s1, s2 = T.span("a"), T.span("b")
        assert s1 is s2
        with s1 as s:
            assert s.tag(x=1) is s  # tag() chains harmlessly
        assert T.current_span() is None

    def test_nesting_and_parent_attribution(self):
        buf = T.enable(capacity=64)
        with T.span("outer", cat="test"):
            assert T.current_span().name == "outer"
            with T.span("inner", cat="test", device_class="big") as sp:
                assert T.current_span() is sp
                sp.tag(block_source="tuned")
            assert T.current_span().name == "outer"
        assert T.current_span() is None

        by_name = {e.name: e for e in buf.events}
        assert by_name["inner"].parent == "outer"
        assert by_name["outer"].parent is None
        assert by_name["inner"].args["device_class"] == "big"
        assert by_name["inner"].args["block_source"] == "tuned"
        # inner closed first, so it is recorded first; both are complete
        # events with non-negative durations nested inside the outer.
        assert [e.name for e in buf.events] == ["inner", "outer"]
        assert all(e.ph == "X" and e.dur >= 0.0 for e in buf.events)
        inner, outer = by_name["inner"], by_name["outer"]
        assert outer.ts <= inner.ts
        assert inner.ts + inner.dur <= outer.ts + outer.dur + 1e-6

    def test_exception_restores_stack_and_tags_error(self):
        buf = T.enable()
        with pytest.raises(RuntimeError):
            with T.span("boom"):
                raise RuntimeError("x")
        assert T.current_span() is None
        (ev,) = buf.events
        assert ev.args["error"] == "RuntimeError"

    def test_misnested_exit_drops_only_self(self):
        # Out-of-order exit (possible with manual enter/exit) must not
        # corrupt the rest of the stack.
        T.enable()
        a = T.span("a").__enter__()
        b = T.span("b").__enter__()
        a.__exit__(None, None, None)
        assert T.current_span() is b
        b.__exit__(None, None, None)
        assert T.current_span() is None

    def test_concurrent_threads_have_independent_stacks(self):
        # Mirrors test_execution.TestContextScoping: each thread starts
        # from the default empty stack, so nesting in one thread is
        # invisible to — and unpoppable by — another.
        buf = T.enable(capacity=4096)
        errors = []

        def worker(tag):
            try:
                for _ in range(25):
                    with T.span(f"outer-{tag}"):
                        with T.span(f"inner-{tag}") as sp:
                            assert T.current_span() is sp
                        assert T.current_span().name == f"outer-{tag}"
                    assert T.current_span() is None
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        # Every inner span's parent is its own thread's outer span.
        for ev in buf.events:
            if ev.name.startswith("inner-"):
                tag = ev.name.split("-", 1)[1]
                assert ev.parent == f"outer-{tag}"

    def test_interleaved_async_tasks_have_independent_stacks(self):
        # Two asyncio tasks on one thread hold spans in interleaved
        # order; each task runs in a copied context, so neither sees
        # (or pops) the other's stack.
        import asyncio

        buf = T.enable()

        async def main():
            a_in, b_in = asyncio.Event(), asyncio.Event()

            async def task_a():
                with T.span("task-a"):
                    a_in.set()
                    await b_in.wait()  # b enters while a is inside
                    assert T.current_span().name == "task-a"
                    with T.span("child-a"):
                        pass
                assert T.current_span() is None

            async def task_b():
                await a_in.wait()
                assert T.current_span() is None  # a's span is not visible
                with T.span("task-b"):
                    b_in.set()
                    assert T.current_span().name == "task-b"
                    with T.span("child-b"):
                        pass
                assert T.current_span() is None

            await asyncio.gather(task_a(), task_b())

        asyncio.run(main())
        parents = {e.name: e.parent for e in buf.events}
        assert parents["child-a"] == "task-a"
        assert parents["child-b"] == "task-b"
        assert parents["task-a"] is None
        assert parents["task-b"] is None


# ---------------------------------------------------------------------------
# Buffer bounding + export formats + report CLI round-trip
# ---------------------------------------------------------------------------


class TestBufferAndExport:
    def test_capacity_bounds_and_counts_drops(self):
        buf = T.enable(capacity=4)
        for i in range(7):
            T.instant(f"ev{i}")
        assert len(buf) == 4
        assert buf.dropped == 3
        assert [e.name for e in buf.events] == ["ev3", "ev4", "ev5", "ev6"]
        buf.clear()
        assert len(buf) == 0 and buf.dropped == 0

    def test_enable_is_idempotent_disable_detaches(self):
        buf = T.enable()
        assert T.enable() is buf  # existing buffer kept
        T.instant("x")
        detached = T.disable()
        assert detached is buf and not T.enabled()
        T.instant("after")  # silently dropped: no buffer
        assert [e.name for e in detached.events] == ["x"]

    def test_chrome_trace_structure(self):
        T.enable()
        t0 = time.perf_counter()
        with T.span("outer"):
            T.instant("mark", note="hi")
        T.complete("posthoc", t0, 0.002, device_class="little")
        T.counter("queue", big=3, little=1)
        buf = T.disable()

        chrome = buf.chrome_trace()
        evs = {e["name"]: e for e in chrome["traceEvents"]}
        assert chrome["displayTimeUnit"] == "ms"
        assert evs["outer"]["ph"] == "X" and "dur" in evs["outer"]
        assert evs["mark"]["ph"] == "i" and evs["mark"]["s"] == "t"
        assert evs["mark"]["args"]["parent"] == "outer"
        assert evs["posthoc"]["dur"] == pytest.approx(2000.0, rel=1e-3)  # µs
        assert evs["queue"]["ph"] == "C" and evs["queue"]["args"] == {
            "big": 3, "little": 1,
        }
        json.dumps(chrome)  # must be serializable as-is

    def test_save_load_roundtrip_both_formats(self, tmp_path):
        T.enable()
        with T.span("work", device_class="big"):
            T.instant("tick")
        buf = T.disable()
        native = tmp_path / "trace.json"
        chrome = tmp_path / "chrome.json"
        buf.save(str(native))
        buf.export_chrome_trace(str(chrome))

        ev_n, meta_n = report.load_events(str(native))
        ev_c, meta_c = report.load_events(str(chrome))
        assert meta_n["format"] == "native" and meta_c["format"] == "chrome"
        assert {e["name"] for e in ev_n} == {e["name"] for e in ev_c} == {
            "work", "tick",
        }
        # Chrome stores µs; load_events normalizes back to seconds.
        wn = next(e for e in ev_n if e["name"] == "work")
        wc = next(e for e in ev_c if e["name"] == "work")
        assert wc["dur"] == pytest.approx(wn["dur"], rel=1e-3)
        with pytest.raises(ValueError):
            bad = tmp_path / "bad.json"
            bad.write_text("[1, 2]")
            report.load_events(str(bad))

    def test_truncated_native_trace_is_salvaged(self, tmp_path):
        # The ISSUE-10 robustness contract: a trace torn mid-write (killed
        # engine, full disk) degrades to a salvage scan — every record
        # that still parses is kept, the torn tail is counted, and the
        # loader never raises.
        T.enable()
        for i in range(6):
            T.instant(f"ev{i}", k=i)
        buf = T.disable()
        p = tmp_path / "t.json"
        buf.save(str(p))
        text = p.read_text()
        # Tear the file inside the LAST event record (native format sorts
        # keys, so "events" is the final array in the file).
        p.write_text(text[: text.rfind("{") + 8])

        events, meta = report.load_events(str(p))
        assert meta["format"] == "native"
        assert meta["skipped_records"] >= 1
        names = [e["name"] for e in events]
        assert names == [f"ev{i}" for i in range(5)]  # all but the torn one
        assert events[0]["args"] == {"k": 0}

    def test_truncated_chrome_trace_is_salvaged(self, tmp_path):
        T.enable()
        with T.span("work"):
            T.instant("tick")
        buf = T.disable()
        p = tmp_path / "c.json"
        buf.export_chrome_trace(str(p))
        text = p.read_text()
        # Tear the file inside the last record ("work" closes after the
        # instant, so it serializes last).
        p.write_text(text[: text.rfind('"name": "work"') + 8])

        events, meta = report.load_events(str(p))
        assert meta["format"] == "chrome"
        assert meta["skipped_records"] >= 1
        assert [e["name"] for e in events] == ["tick"]  # "work" record torn

    def test_clean_trace_reports_zero_skipped(self, tmp_path):
        T.enable()
        T.instant("x")
        T.disable().save(str(tmp_path / "t.json"))
        _, meta = report.load_events(str(tmp_path / "t.json"))
        assert meta["skipped_records"] == 0

    def test_report_cli_warns_on_corrupt_trace(self, tmp_path, capsys):
        # The CLI survives the damaged file and says so in the header —
        # the post-mortem tool must not die of the kill it reports on.
        T.enable()
        for i in range(4):
            T.instant(f"ev{i}")
        buf = T.disable()
        p = tmp_path / "t.json"
        buf.save(str(p))
        text = p.read_text()
        p.write_text(text[: text.rfind("{") + 8])

        rc = report.main([str(p)])
        assert rc == 0
        out = capsys.readouterr().out
        assert "WARNING" in out and "skipped" in out
        assert "ev0" in out

    def test_report_cli_main(self, tmp_path, capsys):
        T.enable()
        with T.span("engine.decode_step"):
            pass
        T.instant("scheduler.rebalance")
        T.disable().save(str(tmp_path / "t.json"))
        out_chrome = tmp_path / "c.json"
        rc = report.main([str(tmp_path / "t.json"), "--chrome", str(out_chrome)])
        assert rc == 0
        text = capsys.readouterr().out
        assert "engine.decode_step" in text
        assert "scheduler.rebalance" in text
        assert json.loads(out_chrome.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
# Metrics registry: validation, idempotence, exposition, snapshot
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_gauge_histogram_basics(self):
        reg = MET.MetricsRegistry()
        c = reg.counter("req_total", "requests")
        c.inc()
        c.inc(2.5)
        with pytest.raises(ValueError):
            c.inc(-1)
        g = reg.gauge("depth", "queue depth")
        g.set(4)
        g.inc()
        g.dec(2)
        h = reg.histogram("lat_seconds", buckets=(0.01, 0.1, 1.0))
        for v in (0.005, 0.05, 0.5, 5.0):
            h.observe(v)
        snap = reg.snapshot()
        assert snap["req_total"]["samples"][0]["value"] == 3.5
        assert snap["depth"]["samples"][0]["value"] == 3.0
        hs = snap["lat_seconds"]["samples"][0]
        assert hs["count"] == 4
        assert hs["sum"] == pytest.approx(5.555)
        # Cumulative buckets: one observation per band, +Inf == count.
        assert hs["buckets"] == {"0.01": 1, "0.1": 2, "1": 3, "+Inf": 4}
        json.dumps(snap)

    def test_label_validation_and_children(self):
        reg = MET.MetricsRegistry()
        fam = reg.counter("adm_total", labels=("device_class",))
        fam.labels(device_class="big").inc(2)
        fam.labels(device_class="little").inc()
        assert fam.labels(device_class="big") is fam.labels(device_class="big")
        with pytest.raises(ValueError):
            fam.labels(wrong="x")  # exact label-name set required
        with pytest.raises(ValueError):
            fam.inc()  # labeled family has no default child
        with pytest.raises(ValueError):
            reg.counter("bad name")
        with pytest.raises(ValueError):
            reg.counter("ok", labels=("bad-label",))

    def test_idempotent_reregistration_and_mismatch(self):
        reg = MET.MetricsRegistry()
        a = reg.counter("x_total", "help", labels=("k",))
        assert reg.counter("x_total", "other help", labels=("k",)) is a
        with pytest.raises(ValueError):
            reg.gauge("x_total")  # kind mismatch
        with pytest.raises(ValueError):
            reg.counter("x_total", labels=("other",))  # label mismatch

    def test_prometheus_exposition_format(self):
        reg = MET.MetricsRegistry()
        c = reg.counter("req_total", "requests served", labels=("cls",))
        c.labels(cls='wei"rd\\v').inc(3)
        h = reg.histogram("step_seconds", "step time", buckets=(0.5,))
        h.observe(0.25)
        h.observe(2.0)
        text = reg.exposition()
        lines = text.splitlines()
        assert "# HELP req_total requests served" in lines
        assert "# TYPE req_total counter" in lines
        assert 'req_total{cls="wei\\"rd\\\\v"} 3' in lines
        assert "# TYPE step_seconds histogram" in lines
        assert 'step_seconds_bucket{le="0.5"} 1' in lines
        assert 'step_seconds_bucket{le="+Inf"} 2' in lines
        assert "step_seconds_sum 2.25" in lines
        assert "step_seconds_count 2" in lines
        assert text.endswith("\n")


# ---------------------------------------------------------------------------
# Step-time probe: inert when off, measured per-pod times when on
# ---------------------------------------------------------------------------


class TestStepTimeProbe:
    def test_inert_while_observability_disabled(self):
        probe = StepTimeProbe(_biglittle())
        assert not probe.active()
        assert probe(0, [1, 1]) is None
        assert probe.refreshes == 0  # zero work: off-is-free

    def test_measured_times_scale_with_units(self):
        # Deterministic workloads (sleeps) stand in for the probe GEMM:
        # still wall-clock measured under each class's context, but with
        # a controlled skew — little "measures" ~4x slower than big.
        asym = _biglittle()
        probe = StepTimeProbe(
            asym, interval=64, reps=1, probe_shape=(100, 128, 128),
            workloads={
                "big": lambda: time.sleep(0.002),
                "little": lambda: time.sleep(0.008),
            },
            always=True,
        )
        times = probe(0, [4, 2])
        assert probe.refreshes == 1
        assert len(times) == asym.n_pods
        # times[pod] = units * row_seconds[class]: occupancy is explicit,
        # so observe()'s rate u/(u*s) reduces to pure class speed.
        rs_big = probe.last_measured["big"] / 100
        rs_little = probe.last_measured["little"] / 100
        assert times[0] == pytest.approx(4 * rs_big)
        assert times[1] == pytest.approx(2 * rs_little)
        assert rs_little > rs_big
        # Zero units -> zero charged time (pod idle this step).
        assert probe(1, [0, 3])[0] == 0.0
        # Within the interval no re-measurement happens...
        assert probe.refreshes == 1
        # ...but an interval boundary refreshes.
        probe(64, [1, 1])
        assert probe.refreshes == 2
        # The refresh published per-class gauges to the global registry.
        snap = MET.REGISTRY.snapshot()
        classes = {
            s["labels"]["device_class"]
            for s in snap["probe_row_seconds"]["samples"]
        }
        assert {"big", "little"} <= classes

    def test_default_unit_charge_is_one_per_pod(self):
        probe = StepTimeProbe(
            _biglittle(), reps=1,
            workloads={"big": lambda: None, "little": lambda: None},
            always=True,
        )
        times = probe(0)
        assert len(times) == 2 and all(t >= 0.0 for t in times)


# ---------------------------------------------------------------------------
# Engine wiring: traced run emits class-tagged spans + metric families
# ---------------------------------------------------------------------------


ARCH = "internlm2-1.8b"


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config(ARCH).reduced()
    return cfg, Z.init_params(jax.random.PRNGKey(0), cfg)


class TestEngineTelemetry:
    def test_snapshot_is_the_reporting_surface(self, small_model):
        cfg, params = small_model
        eng = ServingEngine(
            cfg, params, _biglittle(), seq_cap=24, slots_per_pod=4,
            class_sharded="off", pod_time_hook=None,
        )
        snap = eng.stats.snapshot()
        json.dumps(snap)
        # Every dataclass field plus the derived throughput/efficiency
        # metrics, nothing hand-mirrored: new fields show up here
        # automatically.
        import dataclasses as dc

        assert set(snap) == {f.name for f in dc.fields(eng.stats)} | {
            "tokens_per_s", "tokens_per_j", "modeled_tokens_per_s"
        }

    def test_traced_generate_emits_spans_and_metrics(self, small_model):
        cfg, params = small_model
        eng = ServingEngine(
            cfg, params, _biglittle(), seq_cap=24, slots_per_pod=4,
            class_sharded="off", pod_time_hook=None,
        )
        prompts = np.asarray(
            np.random.default_rng(3).integers(0, cfg.vocab, (4, 4)), np.int32
        )
        T.enable()
        try:
            eng.generate(prompts, 4)
        finally:
            buf = T.disable()

        names = [e.name for e in buf.events]
        assert "engine.prefill" in names
        assert names.count("engine.decode_step") >= 3
        shards = [e for e in buf.events if e.name == "engine.decode_shard"]
        # Post-hoc completes (zero hot-loop control flow): one shard span
        # per decode step, time-contained in its step.
        assert len(shards) == names.count("engine.decode_step")
        # Single-program mode: the primary class's provenance tags.
        tags = shards[0].args
        assert tags["device_class"] == "big"
        assert "backend" in tags and "block_source" in tags

        snap = MET.REGISTRY.snapshot()
        for key in (
            "engine_queue_depth", "engine_slot_occupancy",
            "engine_admissions_total", "engine_tokens_total",
            "engine_decode_step_seconds",
        ):
            assert key in snap, key
        adm = {
            s["labels"]["device_class"]: s["value"]
            for s in snap["engine_admissions_total"]["samples"]
        }
        assert sum(adm.values()) >= 4  # every admitted request counted

    def test_untraced_generate_records_nothing(self, small_model):
        # The off-is-free contract at the engine level: no buffer, no
        # events, hook inert — generate() behaves exactly as before.
        cfg, params = small_model
        eng = ServingEngine(
            cfg, params, _biglittle(), seq_cap=24, slots_per_pod=4,
            class_sharded="off",  # default "auto" probe, tracing off
        )
        prompts = np.asarray(
            np.random.default_rng(4).integers(0, cfg.vocab, (4, 4)), np.int32
        )
        out = eng.generate(prompts, 4)
        assert out.shape == (4, 8)
        assert not T.enabled()
        assert isinstance(eng.pod_time_hook, StepTimeProbe)
        assert eng.pod_time_hook.refreshes == 0  # probe never fired
        # Calibration stayed frozen at the typed ratios.
        rates = eng.asym.scheduler.rates
        assert rates[0] == pytest.approx(1.0) and rates[1] == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# The loop closes: measured probe times drive a real rebalance
# ---------------------------------------------------------------------------


class TestCalibrationLoopCloses:
    def test_measured_times_trigger_rebalance(self, small_model):
        """Typed ratios say big:little = 4:1, but the probe *measures* the
        opposite skew — so the scheduler must drift off its initial table
        and re-derive the chunk sizes from the measured signal.  This is
        the feedback path PR 5 left open (no fabricated equal-times): the
        probe closes it with honest wall-clock data."""

        cfg, params = small_model
        asym = _biglittle()  # typed init: rates [1.0, 0.25]
        probe = StepTimeProbe(
            asym, interval=4, reps=1, probe_shape=(100, 128, 128),
            # Measured truth contradicts the typed ratio: little is ~4x
            # FASTER than big.  (Sleeps keep the skew deterministic while
            # the probe still takes real wall-clock measurements.)
            workloads={
                "big": lambda: time.sleep(0.004),
                "little": lambda: time.sleep(0.001),
            },
            always=True,
        )
        eng = ServingEngine(
            cfg, params, asym, seq_cap=24, slots_per_pod=8,
            class_sharded="off", pod_time_hook=probe,
        )
        prompts = np.asarray(
            np.random.default_rng(5).integers(0, cfg.vocab, (8, 4)), np.int32
        )

        T.enable()
        try:
            # First wave: the routing table derives from the typed 4:1
            # ratios; decode steps feed measured times into observe().
            eng.generate(prompts, 4)
            sched = asym.scheduler
            assert probe.refreshes >= 1
            # Measured rates inverted the typed ordering...
            assert sched.rates[1] > sched.rates[0]
            # ...far past the hysteresis threshold.
            assert sched.needs_rebalance()
            before = list(sched._last_sizes)

            # Second wave re-routes the same batch size: same n_units, so
            # the re-derivation counts as a rebalance and flips the split
            # toward the measured-faster class.
            eng.generate(prompts, 4)
        finally:
            buf = T.disable()

        after = list(asym.scheduler._last_sizes)
        assert eng.stats.rebalances >= 1
        assert after != before
        assert after[1] > before[1]  # little (measured faster) gained units

        # The rebalance is visible in the trace, with its trigger drift
        # and the before/after chunk sizes.
        rebs = [e for e in buf.events if e.name == "scheduler.rebalance"]
        assert rebs, [e.name for e in buf.events]
        ev = rebs[0].args
        assert ev["drift"] > ev["threshold"]
        assert ev["before"] == before and sum(ev["after"]) == sum(before)
        assert any(e.name == "probe.measured" for e in buf.events)


# ---------------------------------------------------------------------------
# Tuning + harness telemetry satellites
# ---------------------------------------------------------------------------


class TestTuningTelemetry:
    def test_search_emits_span_and_candidate_timings(self):
        from repro.core.blocking import TPU_V5E
        from repro.tuning.tune import _obs_metrics, tune_shapes

        misses0 = _obs_metrics()["cache"].labels(result="miss").value
        T.enable()
        try:
            (res,) = tune_shapes(
                [(512, 512, 512)], spec=TPU_V5E, backend_name="cost-model",
            )
        finally:
            buf = T.disable()
        spans = {e.name: e for e in buf.events}
        search = spans["tuning.search_shape"]
        assert search.args["n_candidates"] == res.n_candidates
        assert search.args["best"] == [res.best.bm, res.best.bk, res.best.bn]
        cands = [e for e in buf.events if e.name == "tuning.candidate"]
        assert len(cands) == res.n_candidates
        assert all(e.parent == "tuning.search_shape" for e in cands)
        snap = MET.REGISTRY.snapshot()
        assert snap["tuning_candidate_seconds"]["samples"][0]["count"] >= len(cands)
        # The uncached shape counted as a lookup miss.
        assert _obs_metrics()["cache"].labels(result="miss").value == misses0 + 1


class TestHarnessMetadata:
    def test_run_metadata_fields(self):
        from benchmarks.harness import run_metadata

        meta = run_metadata(bench="x", spec="tpu-v5e")
        assert meta["bench"] == "x" and meta["spec"] == "tpu-v5e"
        assert "timestamp" in meta and "jax_version" in meta and "git_sha" in meta
        assert meta["jax_version"] == jax.__version__

    def test_write_json_stamps_meta_and_compare_ignores_it(self, tmp_path):
        from benchmarks.harness import compare_records, load_records, write_json

        records = [{"impl": "xla", "us_per_call": 12.5}]
        p1 = write_json(str(tmp_path / "a.json"), records, bench="t")
        p2 = write_json(str(tmp_path / "b.json"), records, bench="t")
        data = json.loads(open(p1).read())
        assert set(data) == {"meta", "records"}
        assert data["meta"]["bench"] == "t"
        assert load_records(p1) == records
        # Differing meta (timestamps), identical records: no diff.
        assert compare_records(p1, p2) == []
        # A record change IS a diff, named by key.
        write_json(str(tmp_path / "c.json"), [{"impl": "xla", "us_per_call": 13.0}])
        diffs = compare_records(p1, str(tmp_path / "c.json"))
        assert diffs and "us_per_call" in diffs[0]

    def test_load_records_tolerates_legacy_bare_list(self, tmp_path):
        from benchmarks.harness import load_records

        p = tmp_path / "old.json"
        p.write_text('[{"impl": "xla"}]')
        assert load_records(str(p)) == [{"impl": "xla"}]

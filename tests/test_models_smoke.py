"""Per-architecture smoke tests: reduced config, one forward/train/decode
step on CPU, asserting shapes and finiteness (the assignment's required
smoke tier; full configs are exercised only via the dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import model_zoo as Z

ARCHS = list_configs()


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    if cfg.family == "encdec":
        out["frames"] = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.bfloat16)
        out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    elif cfg.embed_inputs:
        out["embeds"] = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)), jnp.bfloat16)
    else:
        out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    return out


@pytest.fixture(scope="module")
def zoo():
    """Init each reduced arch once per module (zamba tracing is slow)."""

    out = {}
    for name in ARCHS:
        cfg = get_config(name).reduced()
        out[name] = (cfg, Z.init_params(jax.random.PRNGKey(0), cfg))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(zoo, arch):
    cfg, params = zoo[arch]
    batch = _batch(cfg)
    loss, metrics = jax.jit(Z.make_loss_fn(cfg))(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["ce"]) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_grads_finite_and_nonzero(zoo, arch):
    cfg, params = zoo[arch]
    batch = _batch(cfg, seed=1)
    g = jax.grad(lambda p: Z.make_loss_fn(cfg)(p, batch)[0])(params)
    total = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(zoo, arch):
    cfg, params = zoo[arch]
    b, cache_len = 2, 32
    state = Z.init_decode_state(cfg, b, cache_len)
    batch = (
        {"embeds": jnp.zeros((b, 1, cfg.d_model), jnp.bfloat16)}
        if cfg.embed_inputs
        else {"tokens": jnp.ones((b, 1), jnp.int32)}
    )
    logits, new_state = jax.jit(Z.make_decode_fn(cfg))(params, batch, state, jnp.int32(0))
    assert logits.shape == (b, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # state structure is preserved
    assert jax.tree.structure(state) == jax.tree.structure(new_state)


@pytest.mark.parametrize("arch", ["deepseek-7b", "mixtral-8x7b", "mamba2-1.3b"])
def test_prefill_matches_decode_loop(zoo, arch):
    """Decoding token-by-token must reproduce the full-sequence forward
    (the KV-cache / SSM-state correctness test)."""

    cfg, params = zoo[arch]
    s = 8
    batch = _batch(cfg, b=1, s=s, seed=3)
    full_logits = jax.jit(Z.make_prefill_fn(cfg))(params, {"tokens": batch["tokens"]})

    state = Z.init_decode_state(cfg, 1, s)
    decode = jax.jit(Z.make_decode_fn(cfg))
    outs = []
    for t in range(s):
        lg, state = decode(params, {"tokens": batch["tokens"][:, t : t + 1]}, state,
                           jnp.int32(t))
        outs.append(lg)
    step_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=0.15, atol=0.15,  # bf16 compute, different contraction orders
    )
    # and the argmax trajectory agrees (the actual serving contract)
    np.testing.assert_array_equal(
        np.argmax(np.asarray(step_logits, np.float32), -1),
        np.argmax(np.asarray(full_logits, np.float32), -1),
    )


def test_swa_ring_cache_wraps(zoo):
    """Mixtral ring cache: decoding past the window must stay finite and
    use ring semantics (slot = pos % window)."""

    cfg, params = zoo["mixtral-8x7b"]
    window = cfg.swa_window
    assert window is not None
    state = Z.init_decode_state(cfg, 1, window)  # cache capped at window
    decode = jax.jit(Z.make_decode_fn(cfg))
    tok = jnp.ones((1, 1), jnp.int32)
    for t in range(window + 3):  # wrap around
        logits, state = decode(params, {"tokens": tok}, state, jnp.int32(t))
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_param_counts_match_published():
    expect = {
        "deepseek-7b": 6.9e9,
        "qwen2.5-32b": 32.8e9,
        "mixtral-8x7b": 46.7e9,
        "mamba2-1.3b": 1.4e9,
        "qwen2-moe-a2.7b": 14.3e9,
    }
    for name, n in expect.items():
        got = get_config(name).param_count()
        assert abs(got - n) / n < 0.1, f"{name}: {got/1e9:.2f}B vs {n/1e9:.2f}B"


def test_moe_active_params_much_smaller():
    cfg = get_config("mixtral-8x7b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()
